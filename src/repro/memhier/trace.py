"""Access-trace generation for the hierarchy simulator.

A trace is a sequence of :class:`Access` records — the per-grid-step
DMA-level memory behaviour of a streaming instruction or fused program:

  * each vector operand is one sequential *stream* in its own address
    region (streams never alias);
  * per grid step, each input stream reads one block and each output
    stream writes one block (write-only: outputs are produced whole, so
    the §3.1.1 full-block-write skip applies — no fetch-on-write-miss);
  * chained intermediates of a fused :class:`~repro.core.program.Program`
    are ELIDED: they live in VMEM scratch inside the single pallas_call
    and never reach the memory system. This is the fusion layer's whole
    point, and the simulator sees it as missing traffic.

Generators are cheap to re-create, so geometry searches regenerate the
trace per candidate instead of materialising it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Optional, Sequence

from repro.core.stream import StreamConfig, _bits, round_up

# Streams are placed in disjoint 1-TiB-aligned regions so they can never
# share a cache block.
STREAM_SPACING = 1 << 40


@dataclasses.dataclass(frozen=True)
class Access:
    """One memory access: `kind` is "r" or "w"; `stream` labels the operand."""

    addr: int
    nbytes: int
    kind: str
    stream: str


def stream_trace(n_bytes: int, block_bytes: int,
                 read_streams: Sequence[str],
                 write_streams: Sequence[str] = (),
                 region_base: int = 0) -> Iterator[Access]:
    """Interleaved streaming trace: per step, one block per stream.

    Reads and writes of a step are adjacent (the grid pipeline issues
    them together); the final partial block is truncated to ``n_bytes``.
    ``region_base`` offsets the address regions so independent launches
    (e.g. the stages of an unfused chain) never alias.
    """
    if n_bytes <= 0 or block_bytes <= 0:
        return
    streams = [(s, region_base + i, "r")
               for i, s in enumerate(read_streams)]
    streams += [(s, region_base + len(read_streams) + i, "w")
                for i, s in enumerate(write_streams)]
    n_steps = -(-n_bytes // block_bytes)
    for step in range(n_steps):
        off = step * block_bytes
        size = min(block_bytes, n_bytes - off)
        for name, region, kind in streams:
            yield Access(region * STREAM_SPACING + off, size, kind, name)


def trace_config(cfg: StreamConfig, n_elems: int, dtype,
                 n_in: int = 1, n_out: int = 1) -> Iterator[Access]:
    """Trace of one streaming instruction at a StreamConfig's geometry."""
    block_bytes = cfg.block_bits // 8
    total = round_up(n_elems * _bits(dtype) // 8, block_bytes)
    return stream_trace(total, block_bytes,
                        [f"in{i}" for i in range(n_in)],
                        [f"out{i}" for i in range(n_out)])


def trace_stage(stage, n_elems: int, dtype,
                region_base: int = 0) -> Iterator[Access]:
    """Trace of one unfused :class:`~repro.core.template.Stage` launch:
    every vector input is read from and every output spilled to memory."""
    bits = _bits(dtype)
    block_bytes = stage.block_rows * stage.block_cols * bits // 8
    total = round_up(n_elems * bits // 8, block_bytes)
    return stream_trace(total, block_bytes,
                        [f"{stage.name}.in{i}" for i in range(stage.n_vec_in)],
                        [f"{stage.name}.out{i}"
                         for i in range(stage.n_vec_out)],
                        region_base=region_base)


def trace_program(program, n_elems: int, dtype,
                  block_rows: Optional[int] = None,
                  block_cols: Optional[int] = None) -> Iterator[Access]:
    """Trace of a fused Program: external inputs + final outputs only.

    Chained intermediates are elided — they are VMEM scratch inside the
    one pallas_call. Geometry defaults to the stages' declared blocks
    (as in ``Program.call_blocks``); the negotiation passes candidates
    explicitly. ``program`` is duck-typed (n_ext_vec_in / n_vec_out /
    stages) so this module never imports :mod:`repro.core.program`.
    """
    stages = program.stages
    if block_rows is None:
        block_rows = max(st.block_rows for st in stages)
    if block_cols is None:
        block_cols = max(st.block_cols for st in stages)
    bits = _bits(dtype)
    block_bytes = block_rows * block_cols * bits // 8
    total = round_up(n_elems * bits // 8, block_bytes)
    return stream_trace(total, block_bytes,
                        [f"in{i}" for i in range(program.n_ext_vec_in)],
                        [f"out{i}" for i in range(program.n_vec_out)])


def trace_program_unfused(program, n_elems: int, dtype) -> Iterator[Access]:
    """The same chain as N separate launches: every stage's inputs re-read
    from and outputs spilled to memory — the fusion counterfactual.

    Stages get disjoint address regions: each launch re-streams its
    operands from DRAM (a pallas_call's VMEM staging is not a coherent
    cache surviving between calls).
    """
    base = 0
    for st in program.stages:
        yield from trace_stage(st, n_elems, dtype, region_base=base)
        base += st.n_vec_in + st.n_vec_out


def demand_bytes(trace: Iterable[Access]) -> int:
    """Total bytes an (exhaustible) trace demands — consumes the trace."""
    return sum(a.nbytes for a in trace)
