"""Cache-hierarchy description — paper §3.1 as data.

The paper's memory contribution is a hierarchy optimised for *bandwidth*
rather than latency:

  * §3.1.1 — the DL1 block equals VLEN, so a full-vector store covers a
    whole block and the fetch-on-write-miss read is skipped entirely
    (``full_block_write_skips_fetch``);
  * §3.1.2 — the last-level cache uses very wide blocks (8192–16384 bit)
    so that one block maps onto one long DRAM burst, amortising the
    fixed AXI handshake over many beats;
  * §3.1.3 — the wide LLC block is *sub-blocked*: validity is tracked at
    sub-block (VLEN) granularity, so sub-blocks stream out to DL1 before
    the burst completes and partial writes need no read-fill.

:class:`CacheLevel` captures one level's geometry and write policy,
:class:`LastLevelCache` adds the sub-block granularity, and
:class:`Hierarchy` stacks levels over the DRAM/HBM
:class:`~repro.core.burst_model.BurstModel` (the §3.1.2 burst law — one
LLC-block fill or writeback is one burst).

Two presets anchor the two platforms the repo models:

  * :data:`PAPER_ULTRA96` — the paper's Ultra96 softcore: 256-bit VLEN /
    DL1 blocks, a 16384-bit sub-blocked LLC, AXI DRAM (Fig. 3 left).
  * :data:`TPU_V5E` — the TPU analogue: the (8, 128) fp32 register tile
    as "DL1", VMEM as the very wide sub-blocked staging level whose
    block is the per-grid-step HBM→VMEM DMA, HBM as DRAM.

The trace-driven engine that runs a hierarchy lives in
:mod:`repro.memhier.predict`; access traces come from
:mod:`repro.memhier.trace`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.burst_model import BurstModel, PAPER_AXI, TPU_V5E_HBM
from repro.core.stream import VMEM_BYTES


@dataclasses.dataclass(frozen=True)
class CacheLevel:
    """One cache level: block geometry, capacity, write policy, port speed.

    write_allocate:
        on a write miss, fetch the block from below before writing
        (classic fetch-on-write-miss). Ignored when the write covers
        whole (sub-)blocks and ``full_block_write_skips_fetch`` is set.
    full_block_write_skips_fetch:
        paper §3.1.1 — a write covering a whole block (whole sub-blocks
        for a sub-blocked level) allocates without reading below.
    bandwidth:
        bytes/s the level's ports sustain (demand + fill + writeback
        traffic all cross them); the per-level busy-time term.
    hit_latency_s:
        per-access latency; streaming pipelines mostly hide it, so the
        presets keep it small but it participates in busy time.
    n_ways:
        set associativity: blocks per set, with set-indexed replacement
        inside each set — so reuse-heavy traces pay conflict misses when
        hot lines collide on a set. ``None`` (the default) keeps the
        level fully associative, the pre-associativity behaviour. ``1``
        is direct-mapped. When ``n_ways`` does not divide ``n_blocks``,
        the remainder blocks are unreachable (the modeled capacity is
        ``n_sets * n_ways``, as in real hardware where sets × ways
        defines the cache) — prefer geometries where it divides.
    policy:
        replacement policy inside each set: ``"lru"`` (the default,
        recency order refreshed on every hit), ``"fifo"`` (insertion
        order only — hits do not refresh, the cheap-BRAM option a
        softcore LLC would actually ship), or ``"plru"`` (bit-pseudo-LRU:
        one MRU bit per line, victim is the first line whose bit is
        clear; when setting a bit would set them all, the others reset).
        The engine in :mod:`repro.memhier.predict` honours the policy on
        hits and on victim selection.
    """

    POLICIES = ("lru", "fifo", "plru")

    name: str
    block_bytes: int
    capacity_bytes: int
    bandwidth: float
    hit_latency_s: float = 0.0
    write_allocate: bool = True
    full_block_write_skips_fetch: bool = True
    n_ways: Optional[int] = None
    policy: str = "lru"

    def __post_init__(self):
        if self.block_bytes <= 0:
            raise ValueError(f"{self.name}: block_bytes must be positive")
        if self.capacity_bytes < self.block_bytes:
            raise ValueError(
                f"{self.name}: capacity {self.capacity_bytes} B holds no "
                f"{self.block_bytes}-byte block")
        if self.bandwidth <= 0:
            raise ValueError(f"{self.name}: bandwidth must be positive")
        if self.n_ways is not None and self.n_ways <= 0:
            raise ValueError(f"{self.name}: n_ways must be positive "
                             f"(None = fully associative)")
        if self.policy not in self.POLICIES:
            raise ValueError(f"{self.name}: unknown replacement policy "
                             f"{self.policy!r}; have {self.POLICIES}")

    @property
    def n_blocks(self) -> int:
        return self.capacity_bytes // self.block_bytes

    @property
    def n_sets(self) -> int:
        """Sets the block index hashes over (1 = fully associative)."""
        if self.n_ways is None:
            return 1
        return max(1, self.n_blocks // self.n_ways)

    @property
    def ways(self) -> int:
        """Blocks per set the replacement policy manages (capacity-clamped
        so geometry edits like :meth:`Hierarchy.with_llc_block` cannot
        oversubscribe a shrunken level)."""
        if self.n_ways is None:
            return self.n_blocks
        return min(self.n_ways, self.n_blocks)

    @property
    def sub_bytes(self) -> int:
        """Write-skip granularity; a plain level needs the whole block."""
        return self.block_bytes


@dataclasses.dataclass(frozen=True)
class LastLevelCache(CacheLevel):
    """A very wide, sub-blocked level (paper §3.1.2–3.1.3).

    One block fill/writeback is one DRAM burst; validity at sub-block
    granularity means writes covering whole sub-blocks skip the fill
    even when they don't cover the whole (very wide) block.
    """

    sub_block_bytes: int = 0      # 0 → block_bytes (no sub-blocking)

    def __post_init__(self):
        super().__post_init__()
        sub = self.sub_block_bytes or self.block_bytes
        if self.block_bytes % sub:
            raise ValueError(
                f"{self.name}: block {self.block_bytes} B must hold whole "
                f"{sub}-byte sub-blocks (§3.1.3)")

    @property
    def sub_bytes(self) -> int:
        return self.sub_block_bytes or self.block_bytes


@dataclasses.dataclass(frozen=True)
class ChannelModel:
    """N independent DRAM channels behind the last-level cache
    (DESIGN.md §18).

    Multi-stack semantics: the hierarchy's ``dram`` :class:`BurstModel`
    describes ONE channel, so aggregate bandwidth scales with
    ``n_channels`` — like adding HBM stacks, not like slicing one
    interface. ``peak_bw`` overrides the per-channel peak (``None``
    inherits ``dram.peak_bw``); per-burst ``overhead_s`` always comes
    from ``dram``.

    ``mapping`` places each burst on a channel by its address:

      * ``"interleave"`` — round-robin at ``interleave_bytes``
        granularity, ``(addr // interleave_bytes) % n_channels``: one
        stream spreads over all channels (one-item aggregate bandwidth).
      * ``"pinned"`` — by 1-TiB stream region (the spacing
        :mod:`repro.memhier.trace` places operand streams at), region
        ``% n_channels`` unless ``pins`` maps it explicitly: streams /
        parts own whole channels, so distinct items never collide —
        the lane→channel story the scheduler builds on.
    """

    MAPPINGS = ("interleave", "pinned")
    REGION_BYTES = 1 << 40       # == trace.STREAM_SPACING

    n_channels: int = 1
    mapping: str = "interleave"
    interleave_bytes: int = 4096
    peak_bw: Optional[float] = None
    pins: Optional[tuple[tuple[int, int], ...]] = None  # (region, channel)

    def __post_init__(self):
        if self.n_channels <= 0:
            raise ValueError("n_channels must be positive")
        if self.mapping not in self.MAPPINGS:
            raise ValueError(f"unknown channel mapping {self.mapping!r}; "
                             f"have {self.MAPPINGS}")
        if self.interleave_bytes <= 0:
            raise ValueError("interleave_bytes must be positive")
        for region, ch in self.pins or ():
            if not (0 <= ch < self.n_channels):
                raise ValueError(f"pin {region} -> {ch} outside "
                                 f"{self.n_channels} channels")

    def channel_of(self, addr: int) -> int:
        """The channel serving a burst at ``addr``."""
        if self.n_channels == 1:
            return 0
        if self.mapping == "interleave":
            return (addr // self.interleave_bytes) % self.n_channels
        region = addr // self.REGION_BYTES
        for r, ch in self.pins or ():
            if r == region:
                return ch
        return region % self.n_channels

    def fingerprint(self) -> tuple:
        return ("channels", self.n_channels, self.mapping,
                self.interleave_bytes, self.peak_bw, self.pins)


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """A stack of cache levels (closest to the core first) over DRAM.

    ``dram`` is the existing :class:`BurstModel`: every last-level block
    fill or dirty writeback costs one burst, ``overhead_s + bytes/peak``.

    ``channels`` (optional, DESIGN.md §18) splits DRAM into N
    independent per-channel interfaces; ``None`` and
    ``ChannelModel(n_channels=1)`` are modeled identically (the
    pre-channel single-interface behaviour, bit for bit).
    """

    name: str
    levels: tuple[CacheLevel, ...]
    dram: BurstModel
    channels: Optional[ChannelModel] = None

    def __post_init__(self):
        for above, below in zip(self.levels, self.levels[1:]):
            if below.block_bytes % above.block_bytes:
                raise ValueError(
                    f"{self.name}: {below.name} block ({below.block_bytes} B)"
                    f" must hold whole {above.name} blocks "
                    f"({above.block_bytes} B)")

    @property
    def n_channels(self) -> int:
        return self.channels.n_channels if self.channels else 1

    def with_channels(self, n_channels: int, mapping: str = "interleave",
                      interleave_bytes: int = 4096,
                      peak_bw: Optional[float] = None,
                      pins=None) -> "Hierarchy":
        """This hierarchy with an N-channel DRAM (multi-stack semantics:
        per-channel peak defaults to the full ``dram`` peak, so aggregate
        bandwidth is ``n_channels ×`` the single-channel preset)."""
        ch = ChannelModel(n_channels=n_channels, mapping=mapping,
                          interleave_bytes=interleave_bytes,
                          peak_bw=peak_bw,
                          pins=tuple(pins) if pins else None)
        return dataclasses.replace(self, channels=ch)

    def fingerprint(self) -> tuple:
        """Hashable value identifying this hierarchy's modeled behaviour.

        The dispatch-cache key component in
        :meth:`repro.core.program.Program.negotiate_geometry` (DESIGN.md
        §12): any level edit — a mutated LLC block via
        :meth:`with_llc_block`, a policy change, a different preset —
        yields a different fingerprint, so cached geometries invalidate;
        structurally identical hierarchies share cache entries even
        across distinct objects.
        """
        base = ("hier",
                tuple((type(lv).__name__,) + dataclasses.astuple(lv)
                      for lv in self.levels),
                self.dram.fingerprint())
        # a 1-channel ChannelModel is modeled identically to channels=None
        # (the N=1 identity gate), so both share the legacy fingerprint —
        # and with it every persisted geometry/plan artifact (§14).
        if self.channels is None or self.channels.n_channels == 1:
            return base
        return base + (self.channels.fingerprint(),)

    @property
    def dl1(self) -> CacheLevel:
        return self.levels[0]

    @property
    def llc(self) -> CacheLevel:
        """The level whose block size is the DRAM burst length (§3.1.2)."""
        return self.levels[-1]

    def with_llc_block(self, block_bytes: int) -> "Hierarchy":
        """This hierarchy with the LLC block (= burst length) replaced.

        The geometry-search knob: sweeping it reproduces Fig. 3, and the
        Program block negotiation evaluates candidates through it.
        Capacity is bumped to hold at least 4 blocks; the sub-block
        granularity is kept when it still divides, else collapsed.
        """
        if not self.levels:
            return self
        llc = self.llc
        sub = llc.sub_bytes if block_bytes % llc.sub_bytes == 0 else block_bytes
        repl = dict(
            block_bytes=block_bytes,
            capacity_bytes=max(llc.capacity_bytes, 4 * block_bytes),
        )
        if isinstance(llc, LastLevelCache):
            repl["sub_block_bytes"] = sub
        new_llc = dataclasses.replace(llc, **repl)
        # keep upper levels no wider than the new LLC block
        uppers = tuple(
            lv if block_bytes % lv.block_bytes == 0 else dataclasses.replace(
                lv, block_bytes=block_bytes,
                capacity_bytes=max(lv.capacity_bytes, 4 * block_bytes))
            for lv in self.levels[:-1])
        return dataclasses.replace(self, levels=uppers + (new_llc,))


# -- presets ------------------------------------------------------------------

# The paper's Ultra96 softcore (Fig. 3 left): 256-bit VLEN, DL1 blocks equal
# to VLEN (§3.1.1), a 16384-bit sub-blocked LLC (§3.1.2-3) in PL BRAM, AXI
# DRAM with N_1/2 ≈ 128 B. Port rates: one VLEN per ~150 MHz cycle at DL1
# (4.8 GB/s); the LLC runs the doubled interconnect rate of §3.1.4.
PAPER_ULTRA96 = Hierarchy(
    name="paper_ultra96",
    levels=(
        CacheLevel("dl1", block_bytes=32, capacity_bytes=32 * 1024,
                   bandwidth=4.8e9),
        LastLevelCache("llc", block_bytes=2048, capacity_bytes=512 * 1024,
                       bandwidth=9.6e9, sub_block_bytes=32),
    ),
    dram=PAPER_AXI,
    # the Ultra96 PS exposes a single DDR4 channel to the PL AXI ports
    channels=ChannelModel(n_channels=1),
)

# The TPU v5e analogue: the (8, 128) fp32 tile a kernel body touches per
# step stands in for DL1 (VREGs, effectively infinite port rate), VMEM is
# the very wide sub-blocked staging level — its block is the per-grid-step
# HBM→VMEM DMA, the knob Program.negotiate_geometry sweeps — and HBM is
# the DRAM burst model (N_1/2 ≈ 410 KB: the paper's very-wide-LLC-block
# insight three orders of magnitude up).
TPU_V5E = Hierarchy(
    name="tpu_v5e",
    levels=(
        CacheLevel("vreg", block_bytes=4096, capacity_bytes=64 * 4096,
                   bandwidth=3e12),
        LastLevelCache("vmem", block_bytes=512 * 1024,
                       capacity_bytes=VMEM_BYTES,
                       bandwidth=1.6e12, sub_block_bytes=4096),
    ),
    dram=TPU_V5E_HBM,
    # TPU_V5E_HBM's 819 GB/s is the chip's *aggregate* HBM number; the
    # base preset folds every stack into that one calibrated interface
    # (n_channels=1 == the pre-channel model, bit for bit).
    channels=ChannelModel(n_channels=1),
)

# Scale-out variant (DESIGN.md §18): two HBM stacks, each a full
# TPU_V5E_HBM interface, streams pinned to stacks by 1-TiB region — the
# multi-stack geometry bench_channels measures aggregate scaling on.
TPU_V5E_2STACK = dataclasses.replace(
    TPU_V5E, name="tpu_v5e_2stack",
    channels=ChannelModel(n_channels=2, mapping="pinned"))

PRESETS = {h.name: h for h in (PAPER_ULTRA96, TPU_V5E, TPU_V5E_2STACK)}
