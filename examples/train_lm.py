"""End-to-end driver: train a ~40M-param llama-family model on synthetic
data for a few hundred steps, with checkpointing (CPU-runnable).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

On a real pod, drop --tiny/--steps and pass --arch llama3-8b etc. —
identical code path (repro.launch.train).
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.launch import train

if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--tiny", action="store_true",
                   help="2-layer smoke config instead of ~40M")
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = p.parse_args()

    if args.tiny:
        train.main(["--arch", "llama3-8b", "--reduced",
                    "--steps", str(args.steps), "--batch", "8",
                    "--seq", "128", "--ckpt-dir", args.ckpt_dir])
    else:
        # ~40M params: exercised through the same full-model code path
        import dataclasses
        from repro.configs import llama3_8b
        from unittest import mock
        cfg = dataclasses.replace(
            llama3_8b.config(), n_layers=8, d_model=512, n_heads=8,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=8192,
            param_dtype="float32", act_dtype="float32", attn_chunk=128)
        with mock.patch("repro.configs.get_config", lambda name: cfg):
            train.main(["--arch", "llama3-8b",
                        "--steps", str(args.steps), "--batch", "4",
                        "--seq", "256", "--ckpt-dir", args.ckpt_dir,
                        "--log-every", "10"])
