"""Serve a small model with batched requests: prefill + decode loop.

    PYTHONPATH=src python examples/serve_decode.py --arch hymba-1.5b

With ``--sched`` the decode steps run through the repro.sched predictive
scheduling runtime (deadline accounting against --slo-ms, EWMA-corrected
step predictions, optional replayable --sched-trace JSONL).
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.launch import serve

if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="hymba-1.5b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--sched", action="store_true")
    p.add_argument("--sched-policy", default="edf")
    p.add_argument("--sched-trace", default=None)
    p.add_argument("--slo-ms", type=float, default=50.0)
    args = p.parse_args()
    argv = ["--arch", args.arch, "--reduced",
            "--batch", str(args.batch), "--prompt-len", "64",
            "--gen", str(args.gen), "--temperature", "0.8"]
    if args.sched:
        argv += ["--sched", "--sched-policy", args.sched_policy,
                 "--slo-ms", str(args.slo_ms)]
        if args.sched_trace:
            argv += ["--sched-trace", args.sched_trace]
    serve.main(argv)
