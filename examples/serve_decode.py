"""Serve a small model with batched requests: prefill + decode loop.

    PYTHONPATH=src python examples/serve_decode.py --arch hymba-1.5b
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.launch import serve

if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="hymba-1.5b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--gen", type=int, default=32)
    args = p.parse_args()
    serve.main(["--arch", args.arch, "--reduced",
                "--batch", str(args.batch), "--prompt-len", "64",
                "--gen", str(args.gen), "--temperature", "0.8"])
