"""The paper's two applications (§4.3) end-to-end: sort and prefix-sum a
large array with the custom SIMD instructions, vs their baselines —
plus a DAG-shaped streaming pipeline compiled by the repro.graph
partitioner (branching + shared inputs, not just a hand-fused chain).

    PYTHONPATH=src python examples/sort_prefix_apps.py [--mib 16]
"""
import argparse
import sys
import time
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def timed(label, fn, *args):
    jax.block_until_ready(fn(*args))          # compile
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"{label:32s} {dt*1e3:9.2f} ms")
    return out, dt


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--mib", type=int, default=16)
    args = p.parse_args()
    n = args.mib * (1 << 20) // 4
    npow = 1 << (n.bit_length() - 1)
    rng = np.random.default_rng(0)

    print(f"== sorting {npow/1e6:.1f}M int32 keys (paper §4.3.1) ==")
    keys = jnp.asarray(rng.integers(-2**31, 2**31 - 1, npow), jnp.int32)
    net = jax.jit(lambda v: ops.sortnet_mergesort(v[None], max_kernel_width=4096)[0])
    lib = jax.jit(lambda v: jnp.sort(v))
    s1, t1 = timed("sortnet mergesort (c2+c1)", net, keys)
    s2, t2 = timed("base-core library sort", lib, keys)
    assert bool(jnp.all(s1 == s2)), "sort mismatch!"
    print(f"   verified identical; ratio {t2/t1:.2f}x")

    print(f"== prefix sum over {npow/1e6:.1f}M floats (paper §4.3.2) ==")
    x = jnp.asarray(rng.standard_normal(npow), jnp.float32)
    vec = jax.jit(lambda v: ops.prefix_sum(v[None])[0])
    base = jax.jit(lambda v: jnp.cumsum(v))
    p1, t1 = timed("c3_prefixsum (HS + carry)", vec, x)
    p2, t2 = timed("base-core cumsum", base, x)
    err = float(jnp.max(jnp.abs(p1 - p2)) / (jnp.max(jnp.abs(p2)) + 1e-9))
    print(f"   rel err {err:.2e}; ratio {t2/t1:.2f}x")

    print("== DAG pipeline via the graph compiler (§6 exploration) ==")
    from repro.graph import partition
    from repro.memhier import TPU_V5E

    g = ops.c0_pipeline_graph("axpby_residual")
    plan = partition(g, model=TPU_V5E, n_elems=npow)
    print(plan.describe())
    n = min(npow, 1 << 16)          # interpret mode on CPU: keep it small
    xa = jnp.asarray(rng.standard_normal(n), jnp.float32)
    ba = jnp.asarray(rng.standard_normal(n), jnp.float32)
    mode = "kernel" if jax.default_backend() == "tpu" else "interpret"
    out, res = plan(xa, ba, 2.0, 0.5, mode=mode)
    ref_out, ref_res = plan.ref(xa, ba, 2.0, 0.5)
    assert bool(jnp.allclose(out, ref_out, rtol=1e-6, atol=1e-6))
    assert bool(jnp.allclose(res, ref_res, rtol=1e-6, atol=1e-6))
    t_plan = plan.predicted_time() * 1e6
    t_unf = partition(g, model=TPU_V5E, n_elems=npow,
                      method="singletons").predicted_time() * 1e6
    print(f"   plan matches its ref oracle; memhier-predicted "
          f"{t_plan:.1f} us vs {t_unf:.1f} us unfused "
          f"({t_unf/t_plan:.2f}x)")
