"""Quickstart: define a custom SIMD instruction in ~20 lines (paper Alg. 1).

    PYTHONPATH=src python examples/quickstart.py

The paper's usability claim: drop a few lines into the provided template
and get a pipelined, streaming custom instruction. Here we define
`c7_absmax_scale` — normalise each vector block by the running absmax of
the stream so far (a *stateful* streaming op, the kind fixed SIMD ISAs
can't express in one instruction) — register it in the ISA, validate the
Pallas kernel against its oracle, and call it from jitted code.
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels  # registers the c0-c6 ISA
from repro.core import isa
from repro.core.isa import Instruction, OperandSpec
from repro.core.template import KernelTemplate

# ---- 1. the user code: one block body (the yellow lines in Alg. 1) --------

def body(scalars, ins, outs, carry, step):
    blk = ins[0][...]
    m = jnp.maximum(carry[...], jnp.max(jnp.abs(blk), axis=-1,
                                        keepdims=True))
    outs[0][...] = blk / jnp.maximum(m, 1e-9)
    carry[...] = m                     # running absmax carries across calls


TEMPLATE = KernelTemplate(name="c7_absmax_scale", body=body,
                          n_vec_in=1, n_vec_out=1,
                          carry_cols=1, carry_init=0.0)

# ---- 2. the oracle ("the base core runs it in software") -------------------

def ref_block_absmax(x, block):
    rows, cols = x.shape
    xb = x.reshape(rows, cols // block, block)
    blockmax = jnp.max(jnp.abs(xb), axis=-1)
    run = jax.lax.associative_scan(jnp.maximum, blockmax, axis=-1)
    return (xb / jnp.maximum(run[..., None], 1e-9)).reshape(rows, cols)

# ---- 3. register + use ------------------------------------------------------

isa.register(Instruction(
    name="c7_absmax_scale",
    spec=OperandSpec(itype="I'", vector_in=1, vector_out=1),
    ref=lambda x: ref_block_absmax(x, TEMPLATE.block_cols),
    kernel=lambda x, interpret=False: TEMPLATE(x, interpret=interpret),
    pipeline_depth=TEMPLATE.pipeline_depth(),
    doc="streaming blockwise absmax normalisation (stateful demo)",
))

x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 1024)),
                jnp.float32)
ker = isa.call("c7_absmax_scale", x, mode="interpret")
oracle = isa.call("c7_absmax_scale", x, mode="ref")
print("instruction registered:", "c7_absmax_scale" in isa.registry)
print("kernel vs oracle max err:", float(jnp.max(jnp.abs(ker - oracle))))
assert float(jnp.max(jnp.abs(ker - oracle))) < 1e-6

# the ISA inside a jitted program (software path on CPU, kernel on TPU)
@jax.jit
def program(v):
    return isa.call("c7_absmax_scale", v).sum()

print("jitted program:", float(program(x)))
print("registered ISA:", ", ".join(isa.names()))

# ---- 4. serve concurrent programs through the scheduling runtime ----------
# Two tenants submit fused programs concurrently; the runtime coalesces
# same-structure requests into one warm launch, predicts each with the
# memhier cost model (HBM contention included), and reports placements.
from repro.memhier import TPU_V5E
from repro.sched import CostModel, RequestQueue, Scheduler

fused = isa.fuse("c0_scale", "c0_add")        # one reconfigurable region
y = jnp.asarray(np.random.default_rng(1).standard_normal(4096), jnp.float32)
b = jnp.asarray(np.random.default_rng(2).standard_normal(4096), jnp.float32)

queue = RequestQueue()
queue.submit(fused, (2.0, y, b), tenant="A")   # same structure + scalars →
queue.submit(fused, (2.0, b, y), tenant="B")   # ...coalesce into ONE launch
report = Scheduler(queue, cost=CostModel(hierarchy=TPU_V5E), policy="wfq",
                   n_lanes=2, mode="interpret").drain()
for p in report.placements:
    print(f"request {p.seq}: lane {p.lane}, coalesced={p.coalesced}, "
          f"predicted {p.predicted_s * 1e6:.1f} us")
assert np.allclose(np.asarray(report.results[0]),
                   np.asarray(fused(2.0, y, b, mode="ref")), atol=1e-6)
